"""Serving subsystem: static-shape engines over a shared slot/state pool.

Two engines share one set of building blocks:

* :class:`Engine` (``engine.py``) — wave policy: lockstep batches.
* :class:`ContinuousEngine` (``continuous.py``) — slot policy: finished
  slots are refilled from the queue mid-decode (continuous batching).

Building blocks: :class:`Scheduler` (admission / priorities / deadlines),
:class:`StatePool` (per-slot cache rows with scatter/gather primitives),
:class:`ServeMetrics` (TTFT / occupancy / goodput), ``sampling``
(vectorized Gumbel-max).  The continuous engine optionally admits long
prompts chunk-by-chunk (``ServeConfig.prefill_chunk``), interleaving one
prefill chunk with each decode step, and — with
``ServeConfig.prefix_cache_mb`` — reuses recurrent state across requests
through a radix cache of chunk-boundary snapshots
(:class:`PrefixCache`).  See ``docs/serving.md`` and
``docs/prefix_cache.md``.

Self-speculative decoding (``speculative.py`` + ``continuous.py``;
docs/serving.md): ``ServeConfig.speculate_k`` drafts k tokens per burst
with cheap w8 params and verifies them in one batched full-precision
``verify_chunk`` call, restoring rejected rows from an O(1) state
snapshot — outputs stay byte-identical to the non-speculative path
because the continuous engine keys sampling noise on (seed, uid,
position) (``sampling.sample_keyed``).

Fault tolerance (docs/robustness.md): ``ServeConfig.fault_plan`` threads
a :class:`~repro.runtime.faults.FaultInjector` chaos schedule through the
continuous engine; ``max_queue_depth`` bounds admission with explicit
backpressure, ``overload_queue_depth`` adds a degraded overload mode,
``poison_probe`` quarantines NaN/Inf slots, ``backend_fallback`` degrades
the decode mode (pallas -> cumba -> naive) on compiled-call failures, and
``watchdog_action="recover"`` escalates the hang watchdog to engine-level
recovery with bounded retries.

Observability (``tracing.py`` + ``metrics.py``; docs/observability.md):
``ServeConfig.trace`` turns on per-request span tracing through a
:class:`Tracer` (Chrome/Perfetto JSON + JSONL event log, folded into
reports by ``launch/trace_report.py``), ``metrics_every`` emits periodic
metrics snapshots, and :class:`RecompileSentinel` makes the compile-once
discipline a checked invariant.
"""
from repro.runtime.faults import (FaultEvent, FaultInjector,  # noqa: F401
                                  InjectedBackendError, parse_plan)
from repro.serve.continuous import ContinuousEngine  # noqa: F401
from repro.serve.engine import Engine, ServeConfig  # noqa: F401
from repro.serve.metrics import (RateMeter, ServeMetrics,  # noqa: F401
                                 StreamingHistogram, WindowedGauge)
from repro.serve.prefix_cache import PrefixCache  # noqa: F401
from repro.serve.scheduler import Request, Scheduler, bucket_for  # noqa: F401
from repro.serve.speculative import (accept_lengths,  # noqa: F401
                                     emit_counts, needs_rollback)
from repro.serve.state_pool import StatePool  # noqa: F401
from repro.serve.tracing import (NULL_TRACER, NullTracer,  # noqa: F401
                                 RecompileError, RecompileSentinel, Tracer)
