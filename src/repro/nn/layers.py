"""Functional NN layers: linear, norms, RoPE, embeddings, conv1d.

Every layer is a (specs, apply) pair; params are plain dicts.  Activations
are routed through ``core.pwl.activation`` so ActiBA (PWL approximation)
applies uniformly to every architecture that uses SiLU/GeLU/Softplus/sigmoid.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn import quant
from repro.nn.params import ParamSpec

Array = jax.Array


# ----------------------------------------------------------------------------
# Linear
# ----------------------------------------------------------------------------

def linear_specs(d_in: int, d_out: int, *, axes=("embed", "mlp"),
                 bias: bool = False, scale: Optional[float] = None) -> dict:
    specs = {"w": ParamSpec((d_in, d_out), axes, scale=scale)}
    if bias:
        specs["b"] = ParamSpec((d_out,), (axes[1],), init="zeros")
    return specs


def linear(p: dict, x: Array) -> Array:
    """Dense projection; transparently runs the W8 path when the weight
    was quantized (``nn/quant.py``) — every model family's prefill /
    chunked-prefill / decode goes through here, so quantized params need
    no per-family plumbing."""
    w = p["w"]
    if quant.is_quantized(w):
        y = quant.qdot(x, w)
    else:
        y = jnp.dot(x, w, preferred_element_type=jnp.float32)
    if "b" in p:
        y = y + p["b"].astype(jnp.float32)
    return y.astype(x.dtype)


# ----------------------------------------------------------------------------
# Norms
# ----------------------------------------------------------------------------

def norm_specs(d: int, *, norm_type: str = "rmsnorm") -> dict:
    specs = {"scale": ParamSpec((d,), ("embed",),
                                init="zeros" if norm_type == "gemma_rmsnorm"
                                else "ones")}
    if norm_type == "layernorm":
        specs["bias"] = ParamSpec((d,), ("embed",), init="zeros")
    return specs


def norm(p: dict, x: Array, *, norm_type: str = "rmsnorm",
         eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    if norm_type == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps)
        scale = p["scale"].astype(jnp.float32)
        if norm_type == "gemma_rmsnorm":      # gemma stores scale-1
            scale = scale + 1.0
        y = y * scale
    return y.astype(x.dtype)


# ----------------------------------------------------------------------------
# Rotary position embeddings
# ----------------------------------------------------------------------------

def rope(x: Array, positions: Array, *, theta: float = 1e4) -> Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    freqs = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., seq, half)
    cos = jnp.cos(angles)[..., None, :]                        # (..., seq, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int, base: float = 1e4) -> Array:
    """(seq, d) sinusoidal table, built with jnp (no giant HLO constants)."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    inv = 1.0 / (base ** (np.arange(0, d, 2, dtype=np.float32) / d))
    ang = pos * inv[None, :]                                   # (seq, d/2)
    return jnp.stack([jnp.sin(ang), jnp.cos(ang)], axis=-1).reshape(seq, d)


def sinusoidal_position_at(index: Array, d: int, base: float = 1e4) -> Array:
    """(d,) sinusoidal embedding for one dynamic position index."""
    inv = 1.0 / (base ** (np.arange(0, d, 2, dtype=np.float32) / d))
    ang = index.astype(jnp.float32) * inv
    return jnp.stack([jnp.sin(ang), jnp.cos(ang)], axis=-1).reshape(d)


def sinusoidal_positions_at(positions: Array, d: int,
                            base: float = 1e4) -> Array:
    """(..., d) sinusoidal embeddings for an array of dynamic positions
    (chunked prefill: a chunk's absolute positions are traced offsets, so
    the static ``sinusoidal_positions`` table cannot be pre-sliced)."""
    inv = 1.0 / (base ** (np.arange(0, d, 2, dtype=np.float32) / d))
    ang = positions.astype(jnp.float32)[..., None] * inv       # (..., d/2)
    return jnp.stack([jnp.sin(ang), jnp.cos(ang)],
                     axis=-1).reshape(positions.shape + (d,))


# ----------------------------------------------------------------------------
# Embedding
# ----------------------------------------------------------------------------

def embed_specs(vocab: int, d: int) -> dict:
    return {"table": ParamSpec((vocab, d), ("vocab", "embed"), scale=0.02)}


def embed(p: dict, tokens: Array) -> Array:
    return jnp.take(p["table"], tokens, axis=0)


def unembed(p: dict, x: Array) -> Array:
    """Tied logits: x @ table^T in fp32 (contracted in place — no
    materialized transpose, which matters at one-token decode rates)."""
    table = p["table"].astype(x.dtype)
    return jax.lax.dot_general(
        x, table, (((x.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


# ----------------------------------------------------------------------------
# Causal depthwise conv1d (Mamba / RG-LRU front conv)
# ----------------------------------------------------------------------------

def conv1d_specs(d: int, width: int) -> dict:
    return {"w": ParamSpec((width, d), (None, "mlp"), scale=0.5),
            "b": ParamSpec((d,), ("mlp",), init="zeros")}


def causal_conv1d(p: dict, x: Array,
                  state: Optional[Array] = None) -> Tuple[Array, Array]:
    """x: (b, l, d).  Returns (y, new_state) with state (b, width-1, d)."""
    width = p["w"].shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)         # (b, l+w-1, d)
    w = p["w"].astype(jnp.float32)
    y = sum(xp[:, i:i + x.shape[1], :].astype(jnp.float32) * w[i]
            for i in range(width))
    y = y + p["b"].astype(jnp.float32)
    new_state = xp[:, -(width - 1):, :] if width > 1 else state
    return y.astype(x.dtype), new_state


def causal_conv1d_step(p: dict, x: Array, state: Array) -> Tuple[Array, Array]:
    """One decode step of ``causal_conv1d`` without the seq axis.

    x: (b, d); state: (b, width-1, d).  Returns (y (b, d), new_state) —
    the conv-tail shift is a single window reduction instead of per-tap
    slices (the fused-step kernels mirror this exact op order).
    """
    win = jnp.concatenate([state, x[:, None]], axis=1)   # (b, width, d)
    y = jnp.sum(win.astype(jnp.float32) * p["w"].astype(jnp.float32)[None],
                axis=1) + p["b"].astype(jnp.float32)
    return y.astype(x.dtype), win[:, 1:]
