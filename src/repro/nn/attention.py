"""Multi-head attention (GQA/MQA) with KV cache, RoPE, sliding window.

Projections shard along their *flattened* output feature dim (logical axis
"qkv" -> mesh "model"), which stays divisible for every assigned arch even
when kv-head counts (8, 4, 1) are smaller than the model-axis size; XLA's
sharding propagation handles the per-head layout inside the block.

Three execution paths:
  * training / prefill: full attention — XLA einsum (default) or the Pallas
    flash kernel (``use_flash``);
  * decode: single-query attention against the cache (XLA; a matvec).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn import layers
from repro.nn.params import ParamSpec

Array = jax.Array


class KVCache(NamedTuple):
    k: Array  # (b, max_seq, n_kv, head_dim)
    v: Array  # (b, max_seq, n_kv, head_dim)


def attention_specs(cfg) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    return {
        "wq": layers.linear_specs(d, nq * hd, axes=("embed", "qkv"),
                                  bias=cfg.qkv_bias),
        "wk": layers.linear_specs(d, nkv * hd, axes=("embed", "qkv"),
                                  bias=cfg.qkv_bias),
        "wv": layers.linear_specs(d, nkv * hd, axes=("embed", "qkv"),
                                  bias=cfg.qkv_bias),
        "wo": layers.linear_specs(nq * hd, d, axes=("qkv", "embed")),
    }


def _split_heads(x: Array, n: int, hd: int) -> Array:
    return x.reshape(x.shape[:-1] + (n, hd))


# Above this many kv positions the XLA path switches to the blocked
# online-softmax form (flash-in-XLA): O(S) memory instead of O(S^2).
BLOCKED_ATTN_THRESHOLD = 2048
BLOCKED_ATTN_KV_BLOCK = 1024


def blocked_attention(q: Array, k: Array, v: Array, *, causal: bool,
                      window: Optional[int],
                      logit_softcap: Optional[float] = None,
                      block_k: int = BLOCKED_ATTN_KV_BLOCK,
                      probs_bf16: bool = False) -> Array:
    """Flash-style attention in pure XLA: lax.scan over kv blocks with a
    running (max, denom, acc) — the score matrix never materializes.  The
    per-block body is rematerialized, so the backward pass recomputes block
    scores (classic flash memory behaviour).  Differentiable.

    q: (b, s, nq, hd); k, v: (b, t, nkv, hd).
    """
    b, s, nq, hd = q.shape
    t, nkv = k.shape[1], k.shape[2]
    qpg = nq // nkv
    scale = hd ** -0.5
    pad_t = (-t) % block_k
    if pad_t:
        k = jnp.pad(k, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
    nblocks = (t + pad_t) // block_k
    kb = k.reshape(b, nblocks, block_k, nkv, hd)
    vb = v.reshape(b, nblocks, block_k, nkv, hd)
    qg = (q.astype(jnp.float32) * scale).reshape(b, s, nkv, qpg, hd)
    q_ids = jnp.arange(s)[:, None] + (t - s)      # right-aligned

    @jax.checkpoint
    def body(carry, blk):
        m_prev, l_prev, acc = carry
        kblk, vblk, kv0 = blk
        sc = jnp.einsum("bsgqd,btgd->bgqst", qg, kblk.astype(jnp.float32))
        if logit_softcap is not None:
            sc = jnp.tanh(sc / logit_softcap) * logit_softcap
        k_ids = kv0 + jnp.arange(block_k)[None, :]
        mask = k_ids < t                          # padding
        if causal:
            mask = jnp.logical_and(mask, k_ids <= q_ids)
        if window is not None:
            mask = jnp.logical_and(mask, k_ids > q_ids - window)
        sc = jnp.where(mask[None, None, None], sc, -1e30)
        m_cur = jnp.max(sc, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(sc - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        # Flash-standard trick: probabilities in bf16 for the PV matmul
        # halves the dominant score-matrix traffic (opt-in; fp32 acc kept).
        pv = p.astype(jnp.bfloat16) if probs_bf16 else p
        vb_ = vblk.astype(jnp.bfloat16 if probs_bf16 else jnp.float32)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bgqst,btgd->bgqsd", pv, vb_,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, nkv, qpg, s), -1e30, jnp.float32)
    l0 = jnp.zeros((b, nkv, qpg, s), jnp.float32)
    acc0 = jnp.zeros((b, nkv, qpg, s, hd), jnp.float32)
    kv_starts = jnp.arange(nblocks) * block_k
    from repro.core import accounting
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0),
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), kv_starts),
        unroll=accounting.inner_unroll(nblocks))
    out = acc / jnp.maximum(l, 1e-30)[..., None]          # (b,g,q,s,hd)
    out = jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(b, s, nq, hd)
    return out.astype(q.dtype)


def full_attention(q: Array, k: Array, v: Array, *, causal: bool,
                   window: Optional[int], use_flash: bool,
                   flash_interpret: bool = False,
                   logit_softcap: Optional[float] = None,
                   probs_bf16: bool = False) -> Array:
    """q: (b, s, nq, hd); k, v: (b, t, nkv, hd) -> (b, s, nq, hd)."""
    nq, nkv = q.shape[2], k.shape[2]
    if use_flash and logit_softcap is None:
        from repro.kernels import ops as kops
        qh = jnp.moveaxis(q, 2, 1)
        kh = jnp.moveaxis(k, 2, 1)
        vh = jnp.moveaxis(v, 2, 1)
        out = kops.flash_attention(qh, kh, vh, causal=causal, window=window,
                                   interpret=flash_interpret)
        return jnp.moveaxis(out, 1, 2)

    if k.shape[1] > BLOCKED_ATTN_THRESHOLD:
        return blocked_attention(q, k, v, causal=causal, window=window,
                                 logit_softcap=logit_softcap,
                                 probs_bf16=probs_bf16)

    qpg = nq // nkv
    scale = q.shape[-1] ** -0.5
    qf = q.astype(jnp.float32) * scale
    # grouped einsum keeps kv un-replicated: (b, s, g, qpg, hd)
    qg = qf.reshape(q.shape[0], q.shape[1], nkv, qpg, q.shape[3])
    s = jnp.einsum("bsgqd,btgd->bgqst", qg, k.astype(jnp.float32))
    if logit_softcap is not None:
        s = jnp.tanh(s / logit_softcap) * logit_softcap
    sl, tl = s.shape[-2], s.shape[-1]
    q_ids = jnp.arange(sl)[:, None] + (tl - sl)  # right-aligned positions
    k_ids = jnp.arange(tl)[None, :]
    mask = jnp.ones((sl, tl), bool)
    if causal:
        mask &= k_ids <= q_ids
    if window is not None:
        mask &= k_ids > q_ids - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgqst,btgd->bsgqd", p, v.astype(jnp.float32))
    return out.reshape(q.shape).astype(q.dtype)


def decode_attention(q: Array, cache: KVCache, cache_len: Array, *,
                     window: Optional[int] = None,
                     logit_softcap: Optional[float] = None) -> Array:
    """Single-position query against the cache.

    q: (b, 1, nq, hd); cache k/v: (b, T, nkv, hd); cache_len: () or (b,)
    int32 — number of valid positions per row (the new token's kv must
    already be written).  A vector cache_len lets continuous-batching slots
    sit at different offsets.
    """
    b, _, nq, hd = q.shape
    T, nkv = cache.k.shape[1], cache.k.shape[2]
    qpg = nq // nkv
    qg = (q.astype(jnp.float32) * hd ** -0.5).reshape(b, 1, nkv, qpg, hd)
    s = jnp.einsum("bsgqd,btgd->bgqst", qg, cache.k.astype(jnp.float32))
    if logit_softcap is not None:
        s = jnp.tanh(s / logit_softcap) * logit_softcap
    cl = jnp.asarray(cache_len, jnp.int32)
    if cl.ndim == 0:
        cl = jnp.full((b,), cl)
    k_ids = jnp.arange(T)[None, :]
    valid = k_ids < cl[:, None]
    if window is not None:
        valid &= k_ids > (cl[:, None] - 1 - window)
    s = jnp.where(valid[:, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgqst,btgd->bsgqd", p, cache.v.astype(jnp.float32))
    return out.reshape(q.shape).astype(q.dtype)


def chunk_attention(q: Array, k: Array, v: Array, cache: KVCache,
                    offset: Array, *, window: Optional[int] = None,
                    logit_softcap: Optional[float] = None,
                    probs_bf16: bool = False) -> Tuple[Array, KVCache]:
    """Chunked-prefill attention: append this chunk's k/v to the cache at
    per-row ``offset`` and attend the chunk's queries against everything
    cached so far (prefix + the chunk itself).

    q, k, v: (b, s, n, hd) — already RoPE'd at absolute positions;
    offset: (b,) int32 — tokens already consumed per row (the chunk's
    first token sits at absolute position ``offset``).

    Two cache layouts, mirroring the decode path:

    * **linear** (``T > window`` or no window): scatter k/v at
      ``offset + arange(s)`` and mask with per-row absolute positions —
      the multi-query generalization of ``decode_attention``'s vector
      ``cache_len``.
    * **ring** (``T == window``, sliding-window layers): the ring holds
      only the last ``T`` positions, so a chunk longer than the window
      would overwrite keys its own early queries still need.  Attention
      therefore runs over ``[ring-before-write ; chunk]`` with explicit
      per-slot absolute positions, and the ring is rewritten afterwards
      to hold the last ``T`` positions ≤ ``offset + s - 1``.
    """
    b, s, nq, hd = q.shape
    T, nkv = cache.k.shape[1], cache.k.shape[2]
    qpg = nq // nkv
    rows = jnp.arange(b)[:, None]
    off = jnp.asarray(offset, jnp.int32)
    if off.ndim == 0:
        off = jnp.full((b,), off)
    q_pos = off[:, None] + jnp.arange(s)[None, :]              # (b, s)
    qg = (q.astype(jnp.float32) * hd ** -0.5).reshape(b, s, nkv, qpg, hd)
    ring = window is not None and T == window

    def scores(keys):
        sc = jnp.einsum("bsgqd,btgd->bgqst", qg,
                        keys.astype(jnp.float32))
        if logit_softcap is not None:
            sc = jnp.tanh(sc / logit_softcap) * logit_softcap
        return sc

    if not ring:
        cols = q_pos                                           # (b, s)
        ck = cache.k.at[rows, cols].set(k.astype(cache.k.dtype))
        cv = cache.v.at[rows, cols].set(v.astype(cache.v.dtype))
        new_cache = KVCache(ck, cv)
        sc = scores(ck)                                        # (b,g,q,s,T)
        k_ids = jnp.arange(T)[None, None, :]
        valid = k_ids <= q_pos[..., None]                      # (b, s, T)
        if window is not None:
            valid &= k_ids > q_pos[..., None] - window
        sc = jnp.where(valid[:, None, None], sc, -1e30)
        p = jax.nn.softmax(sc, axis=-1)
        pv = p.astype(jnp.bfloat16) if probs_bf16 else p
        cvf = cv.astype(jnp.bfloat16 if probs_bf16 else jnp.float32)
        out = jnp.einsum("bgqst,btgd->bsgqd", pv, cvf,
                         preferred_element_type=jnp.float32)
        return out.reshape(q.shape).astype(q.dtype), new_cache

    # ---- ring buffer (T == window) ---------------------------------------
    slots = jnp.arange(T)[None, :]                             # (1, T)
    last = off[:, None] - 1                                    # (b, 1)
    # Absolute position held by each ring slot before this chunk's write:
    # the largest p < offset with p ≡ slot (mod T); negative = never written.
    ring_pos = last - jnp.mod(last - slots, T)                 # (b, T)
    sc_ring = scores(cache.k)                                  # (b,g,q,s,T)
    valid_ring = (ring_pos[:, None, :] >= 0) & \
        (ring_pos[:, None, :] > q_pos[..., None] - window)     # (b, s, T)
    sc_chunk = scores(k)                                       # (b,g,q,s,s)
    i_ids = jnp.arange(s)[:, None]
    j_ids = jnp.arange(s)[None, :]
    valid_chunk = (j_ids <= i_ids) & (j_ids > i_ids - window)  # (s, s)
    valid_chunk = jnp.broadcast_to(valid_chunk, (b, s, s))
    sc = jnp.concatenate([
        jnp.where(valid_ring[:, None, None], sc_ring, -1e30),
        jnp.where(valid_chunk[:, None, None], sc_chunk, -1e30)], axis=-1)
    p = jax.nn.softmax(sc, axis=-1)
    vals = jnp.concatenate([cache.v.astype(jnp.float32),
                            v.astype(jnp.float32)], axis=1)    # (b, T+s, ...)
    out = jnp.einsum("bgqst,btgd->bsgqd", p, vals,
                     preferred_element_type=jnp.float32)
    # Rewrite the ring with the last T positions ≤ offset + s - 1: slots
    # whose target position falls inside the chunk take the chunk's k/v,
    # the rest keep their current (older prefix) contents.
    new_last = off[:, None] + s - 1                            # (b, 1)
    tgt_pos = new_last - jnp.mod(new_last - slots, T)          # (b, T)
    src = tgt_pos - off[:, None]                               # chunk index
    take = (src >= 0)[..., None, None]
    src_c = jnp.clip(src, 0, s - 1)
    ck = jnp.where(take, k[rows, src_c].astype(cache.k.dtype), cache.k)
    cv = jnp.where(take, v[rows, src_c].astype(cache.v.dtype), cache.v)
    return (out.reshape(q.shape).astype(q.dtype), KVCache(ck, cv))


def apply(params: dict, cfg, x: Array, *, positions: Array,
          cache: Optional[KVCache] = None,
          cache_index: Optional[Array] = None,
          causal: bool = True,
          window: Optional[int] = None,
          kv_source: Optional[Array] = None,
          is_cross: bool = False,
          ) -> Tuple[Array, Optional[KVCache]]:
    """Attention block body (no residual / norm — the model adds those).

    Modes:
      cache=None                      -> training forward, no cache out
      cache given, x.shape[1] > 1,
        cache_index=None              -> whole-sequence prefill: fill cache
                                         from position 0, full attention
      cache given, x.shape[1] > 1,
        cache_index given             -> chunked prefill: append k/v at
                                         (per-row) cache_index and attend
                                         against the cached prefix + chunk
                                         (see ``chunk_attention``)
      cache given, x.shape[1] == 1    -> decode: update cache at cache_index
      is_cross (whisper decoder)      -> k/v from kv_source; at decode time
                                         kv_source may be None (cache reused)
    """
    nq, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    is_cross = is_cross or kv_source is not None
    q = _split_heads(layers.linear(params["wq"], x), nq, hd)

    if is_cross and kv_source is None:
        # decode with precomputed cross-attention cache: skip k/v projection
        assert cache is not None, "cross-attention decode needs a cache"
        k = v = None
    else:
        src = kv_source if is_cross else x
        k = _split_heads(layers.linear(params["wk"], src), nkv, hd)
        v = _split_heads(layers.linear(params["wv"], src), nkv, hd)

    if not is_cross:
        q = layers.rope(q, positions, theta=cfg.rope_theta)
        if k is not None:
            k = layers.rope(k, positions, theta=cfg.rope_theta)

    new_cache = None
    if cache is None:
        out = full_attention(q, k, v, causal=causal and not is_cross,
                             window=window, use_flash=cfg.use_flash,
                             flash_interpret=cfg.flash_interpret,
                             logit_softcap=cfg.attn_logit_softcap,
                             probs_bf16=cfg.attn_probs_bf16)
    elif x.shape[1] > 1 and cache_index is not None and not is_cross:
        # chunked prefill: append at cache_index, attend prefix + chunk.
        out, new_cache = chunk_attention(
            q, k, v, cache, cache_index, window=window,
            logit_softcap=cfg.attn_logit_softcap,
            probs_bf16=cfg.attn_probs_bf16)
    elif x.shape[1] > 1 or (is_cross and k is not None):
        # prefill: write k/v and run full attention.  Windowed layers use a
        # ring cache of size == window; slot(p) = p % window.
        T = cache.k.shape[1]
        s = k.shape[1]
        ring = window is not None and T == window
        if ring and s >= T:
            s0 = s % T
            ck = jnp.roll(k[:, -T:].astype(cache.k.dtype), s0, axis=1)
            cv = jnp.roll(v[:, -T:].astype(cache.v.dtype), s0, axis=1)
        else:
            ck = jax.lax.dynamic_update_slice(
                cache.k, k.astype(cache.k.dtype), (0, 0, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache.v, v.astype(cache.v.dtype), (0, 0, 0, 0))
        new_cache = KVCache(ck, cv)
        out = full_attention(q, k, v, causal=causal and not is_cross,
                             window=window, use_flash=cfg.use_flash,
                             flash_interpret=cfg.flash_interpret,
                             logit_softcap=cfg.attn_logit_softcap,
                             probs_bf16=cfg.attn_probs_bf16)
    else:
        # decode
        if is_cross:
            new_cache = cache
            cache_len = jnp.asarray(cache.k.shape[1], jnp.int32)
            out = decode_attention(q, cache, cache_len,
                                   logit_softcap=cfg.attn_logit_softcap)
        else:
            # cache_index: () — all rows at one position (wave decode) — or
            # (b,) — per-row positions (continuous-batching slots).
            idx = jnp.asarray(cache_index, jnp.int32)
            T = cache.k.shape[1]
            ring = window is not None and T == window
            slot = jnp.mod(idx, T) if ring else idx
            if idx.ndim:
                rows = jnp.arange(k.shape[0])
                ck = cache.k.at[rows, slot].set(k[:, 0].astype(cache.k.dtype))
                cv = cache.v.at[rows, slot].set(v[:, 0].astype(cache.v.dtype))
            else:
                ck = jax.lax.dynamic_update_slice(
                    cache.k, k.astype(cache.k.dtype), (0, slot, 0, 0))
                cv = jax.lax.dynamic_update_slice(
                    cache.v, v.astype(cache.v.dtype), (0, slot, 0, 0))
            new_cache = KVCache(ck, cv)
            cache_len = jnp.minimum(idx + 1, T) if ring else idx + 1
            out = decode_attention(
                q, new_cache, cache_len, window=None if ring else window,
                logit_softcap=cfg.attn_logit_softcap)

    out = out.reshape(out.shape[:2] + (nq * hd,))
    y = layers.linear(params["wo"], out)
    return y, new_cache


def init_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16) -> KVCache:
    shape = (batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def snapshot_keep_len(T: int, index: Optional[int],
                      window: Optional[int]) -> int:
    """Valid KV length of a prefix-state snapshot after ``index`` consumed
    tokens — the byte-accounting rule for cached attention state
    (``serve/prefix_cache.py``):

    * **ring** caches (``T == window``, sliding-window layers) hold at most
      the last ``window`` positions whatever ``index`` is, and slot
      occupancy is position-dependent (``p % T``), so the whole ring is
      the snapshot — already window-clipped by construction;
    * **linear** caches are valid on ``[0, index)`` only; everything past
      the prefix is zero and need not be stored.

    ``index=None`` means "unknown / keep everything" (full-row clones).
    """
    if window is not None and T == window:
        return T
    return T if index is None else max(0, min(int(index), T))
