"""Gated MLPs (SwiGLU / GeGLU / plain) with ActiBA-aware activations.

When ``xamba.actiba`` is on, the gate activation is the PWL approximation;
with ``pallas`` modes the whole gated unit runs through the drain-fused
``matmul_pwl`` kernel (activation evaluated during the matmul drain, the
paper's vertical fusion).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import pwl
from repro.nn import layers, quant

Array = jax.Array

_ACT_FOR_MLP = {"swiglu": "silu", "geglu": "gelu", "mlp": "gelu"}


def mlp_specs(cfg, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    if cfg.mlp_type in ("swiglu", "geglu"):
        return {
            "wi": layers.linear_specs(d, f, axes=("embed", "mlp")),
            "wg": layers.linear_specs(d, f, axes=("embed", "mlp")),
            "wo": layers.linear_specs(f, d, axes=("mlp", "embed")),
        }
    return {
        "wi": layers.linear_specs(d, f, axes=("embed", "mlp")),
        "wo": layers.linear_specs(f, d, axes=("mlp", "embed")),
    }


def apply(params: dict, cfg, x: Array) -> Array:
    act_name = _ACT_FOR_MLP[cfg.mlp_type]
    xamba = cfg.xamba
    use_pallas = xamba is not None and xamba.actiba and \
        xamba.cumba in ("pallas", "pallas_interpret")

    if cfg.mlp_type in ("swiglu", "geglu"):
        if use_pallas:
            from repro.kernels import ops as kops
            table = pwl.get_table(act_name, segments=xamba.actiba_segments,
                                  lo=xamba.actiba_range[0],
                                  hi=xamba.actiba_range[1],
                                  adaptive=xamba.actiba_adaptive)
            x2 = x.reshape(-1, x.shape[-1])
            wg, wi = params["wg"]["w"], params["wi"]["w"]
            interp = xamba.cumba == "pallas_interpret"
            if quant.is_quantized(wg):
                # W8 + ActiBA composed: int8 tiles dequantized in-register,
                # PWL epilogue on the rescaled accumulator in the drain.
                h = kops.qmatmul(x2, wg.q, wg.scale, table=table,
                                 qv=wi.q, vscale=wi.scale, interpret=interp)
            else:
                h = kops.matmul_pwl(x2, wg, table, wi, interpret=interp)
            h = h.reshape(x.shape[:-1] + (h.shape[-1],))
        else:
            act = pwl.activation(act_name, xamba)
            h = act(layers.linear(params["wg"], x)) * layers.linear(params["wi"], x)
        return layers.linear(params["wo"], h.astype(x.dtype))

    act = pwl.activation(act_name, xamba)
    h = act(layers.linear(params["wi"], x))
    return layers.linear(params["wo"], h.astype(x.dtype))
