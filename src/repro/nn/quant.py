"""W8 weight-only quantization: int8 per-channel symmetric weights.

XAMBA's Step-3 trades accuracy for the NPU's low-precision datapath; the
serving-backend analogue is weight-only int8.  Full-size single-token
decode is weight-bandwidth-bound (see ``docs/benchmarks.md``), so halving
or quartering the bytes behind every big matmul translates near-linearly
into tok/s — without touching the fp32 state recurrences that make SSM
decode numerically stable.

Scheme
------
* **per-channel symmetric**: for a ``(k, n)`` linear weight, each output
  channel ``j`` stores ``q[:, j] = round(w[:, j] / scale[j])`` with
  ``scale[j] = max|w[:, j]| / 127`` — int8 payload + fp32 scale row.
  Per-channel (not per-tensor) keeps the round-trip error proportional to
  each channel's own range, which is what lets the greedy continuation
  track the fp32 model.
* **weight-only**: activations stay fp32/bf16.  Dequantization is exact
  (``deq = q * scale``), so the only error is the rounding at quantize
  time — there is no activation-quantization noise and the decode /
  prefill / chunked-prefill paths all see identical weights.
* **skip-list**: norms, embeddings, biases, convs and the small SSM
  parameters (``A_log``, ``dt_bias``, ``D``, ``dt_proj``, ``x_proj``, the
  MoE router) stay fp — they are a rounding error of total bytes but
  carry the recurrence dynamics (and the fused Pallas decode-step kernels
  consume them directly).

Execution backends (``QuantTensor.backend``, static jit metadata):

* ``"xla"``              — ``lax.dot_general`` directly on the int8
  payload (mixed-dtype dot: XLA upconverts in-register; the weight is
  *read* from memory as int8) with the per-channel scale applied to the
  fp32 accumulator.  This is the portable fallback every mode can run.
* ``"pallas"`` / ``"pallas_interpret"`` — the fused dequant-matmul kernel
  (``kernels/qmatmul.py``): int8 tiles dequantized in-register in VMEM,
  per-channel scale (and optionally the ActiBA PWL epilogue) applied in
  the drain phase.

``QuantTensor`` is a registered pytree node whose children are the int8
payload and the scale, so the existing machinery — ``decode_view``'s
per-layer pre-slicing, ``lax.scan`` over stacked layers, checkpoint-style
tree maps — works unchanged on quantized params: a stacked ``(L, k, n)``
weight quantizes to ``q (L, k, n)`` + ``scale (L, 1, n)`` and slicing
layer ``i`` slices both leaves.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

# Backends a QuantTensor can execute on (static aux data: switching the
# backend retraces, carrying it in the pytree leaf would not).
QUANT_BACKENDS = ("xla", "pallas", "pallas_interpret")

# ``XambaConfig.quant`` mode -> execution backend.
MODE_BACKENDS = {
    "w8": "xla",
    "w8_pallas": "pallas",
    "w8_pallas_interpret": "pallas_interpret",
}

# Param-tree path components whose linear weights stay fp (see module
# docstring).  Matched against every path component, so e.g. the conv
# inside any mixer is skipped wherever it lives.
DEFAULT_SKIP = frozenset({
    "conv",       # depthwise conv taps: tiny, consumed raw by fused kernels
    "dt_proj",    # mamba1 dt up-projection: small, raw input to the kernel
    "x_proj",     # mamba1 dt/B/C projection: small, raw input to the kernel
    "router",     # MoE router: tiny and routing-critical
    "embed",      # embedding / tied unembedding table
})

# Smallest weight worth quantizing: below this the scale row overhead and
# the extra dequant op cost more than the bytes saved.
DEFAULT_MIN_DIM = 32


@jax.tree_util.register_pytree_node_class
class QuantTensor:
    """int8 payload + fp32 per-channel scale for one linear weight.

    ``q``: int8 ``(..., k, n)``; ``scale``: fp32 ``(..., 1, n)`` (the
    contraction axis kept as 1 so any leading stacking axis slices both
    leaves identically)."""

    __slots__ = ("q", "scale", "backend")

    def __init__(self, q, scale, backend: str = "xla"):
        self.q = q
        self.scale = scale
        self.backend = backend

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        return (self.q, self.scale), self.backend

    @classmethod
    def tree_unflatten(cls, backend, children):
        q, scale = children
        return cls(q, scale, backend)

    # -- conveniences -------------------------------------------------------
    @property
    def shape(self) -> Tuple[int, ...]:
        return tuple(self.q.shape)

    @property
    def ndim(self) -> int:
        return self.q.ndim

    def with_backend(self, backend: str) -> "QuantTensor":
        if backend not in QUANT_BACKENDS:
            raise ValueError(
                f"backend {backend!r} not in {QUANT_BACKENDS}")
        return QuantTensor(self.q, self.scale, backend)

    def __repr__(self):
        return (f"QuantTensor(shape={self.shape}, "
                f"backend={self.backend!r})")


def is_quantized(x) -> bool:
    return isinstance(x, QuantTensor)


# ----------------------------------------------------------------------------
# Quantize / dequantize
# ----------------------------------------------------------------------------

def quantize_tensor(w: Array, backend: str = "xla") -> QuantTensor:
    """Per-channel symmetric int8 over the last axis of ``w`` (ndim >= 2);
    the reduction runs over the contraction axis (-2) only, so a stacked
    ``(L, k, n)`` weight gets an independent scale per (layer, channel)."""
    if w.ndim < 2:
        raise ValueError(f"quantize_tensor needs ndim >= 2, got {w.shape}")
    wf = jnp.asarray(w, jnp.float32)
    amax = jnp.max(jnp.abs(wf), axis=-2, keepdims=True)       # (..., 1, n)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(wf / scale), -127, 127).astype(jnp.int8)
    return QuantTensor(q, scale, backend)


def dequantize(qt: QuantTensor) -> Array:
    """Exact fp32 reconstruction of the quantized weight."""
    return qt.q.astype(jnp.float32) * qt.scale


def maybe_dequant(w) -> Array:
    """Pass raw arrays through; materialize QuantTensors to fp32 (used by
    call sites that feed weights into kernels with fp-only signatures —
    the dequant runs in-program, the weight is still *stored* as int8)."""
    return dequantize(w) if is_quantized(w) else w


def roundtrip_error_bound(qt: QuantTensor) -> Array:
    """Elementwise bound on ``|w - dequantize(quantize(w))|``: half a
    quantization step per channel (+ float slack); the round-trip test
    pins the implementation to it."""
    return 0.5 * qt.scale + 1e-6


# ----------------------------------------------------------------------------
# Param-tree quantization
# ----------------------------------------------------------------------------

def _should_quantize(path: Tuple[str, ...], node: dict, skip, min_dim: int
                     ) -> bool:
    w = node.get("w")
    if not isinstance(w, (jax.Array, np.ndarray)) or w.ndim < 2:
        return False
    if any(part in skip for part in path):
        return False
    return min(w.shape[-1], w.shape[-2]) >= min_dim


def quantize_params(params: Any, *, backend: str = "xla",
                    skip: Sequence[str] = DEFAULT_SKIP,
                    min_dim: int = DEFAULT_MIN_DIM) -> Any:
    """Quantize every big linear weight in a params pytree.

    Walks the nested dict/list/tuple structure; any dict that carries a
    ``"w"`` array (the ``layers.linear_specs`` layout) is a candidate —
    quantized in place unless a path component is on the skip-list or the
    weight is too small.  Everything else (norm scales, biases,
    embeddings, conv taps, SSM params, MoE expert tensors) passes through
    untouched.  Works on stacked and per-layer layouts alike; run it
    BEFORE ``decode_view`` so the sliced view shares the int8 buffers.
    """
    if backend not in QUANT_BACKENDS:
        raise ValueError(f"backend {backend!r} not in {QUANT_BACKENDS}")
    skip = frozenset(skip)

    def walk(node, path):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if k == "w" and _should_quantize(path, node, skip, min_dim):
                    out[k] = quantize_tensor(v, backend)
                else:
                    out[k] = walk(v, path + (k,))
            return out
        if isinstance(node, (list, tuple)):
            t = type(node)
            return t(walk(v, path + (str(i),))
                     for i, v in enumerate(node))
        return node

    return walk(params, ())


def quantize_params_for_mode(params: Any, quant_mode: str, **kw) -> Any:
    """``XambaConfig.quant``-keyed entry point: ``"none"`` passes params
    through, the ``w8*`` modes quantize onto the matching backend."""
    if quant_mode in (None, "none"):
        return params
    if quant_mode not in MODE_BACKENDS:
        raise ValueError(
            f"quant mode {quant_mode!r} not in "
            f"{('none',) + tuple(MODE_BACKENDS)}")
    return quantize_params(params, backend=MODE_BACKENDS[quant_mode], **kw)


def quant_summary(params: Any) -> Dict[str, float]:
    """Byte accounting for logging: actual stored bytes vs what the same
    pytree would weigh all-fp32 (EVERY leaf counted at 4 bytes/element on
    the equiv side, so the ratio is well-defined whether the fp leaves
    are fp32 or bf16 — it is "vs an all-fp32 pytree", not "vs the dtype
    you happened to init with")."""
    n_q = n_fp = 0
    bytes_q = bytes_fp = fp32_equiv = 0
    for leaf in jax.tree.leaves(params, is_leaf=is_quantized):
        if is_quantized(leaf):
            n_q += 1
            bytes_q += leaf.q.size * leaf.q.dtype.itemsize + \
                leaf.scale.size * leaf.scale.dtype.itemsize
            fp32_equiv += leaf.q.size * 4
        else:
            n_fp += 1
            bytes_fp += leaf.size * leaf.dtype.itemsize
            fp32_equiv += leaf.size * 4
    total = bytes_q + bytes_fp
    return {"quantized_tensors": n_q, "fp_tensors": n_fp,
            "bytes": total, "bytes_fp32_equiv": fp32_equiv,
            "compression": round(fp32_equiv / total, 2) if total else 1.0}


# ----------------------------------------------------------------------------
# Quantized matmul dispatch
# ----------------------------------------------------------------------------

def qdot(x: Array, qt: QuantTensor) -> Array:
    """``x @ dequantize(qt)`` in fp32, executed on the tensor's backend.

    ``x``: ``(..., k)``; ``qt.q``: ``(k, n)`` (stacked weights must be
    sliced to a layer before application, same as raw weights).  The XLA
    backend issues ``dot_general`` directly on the int8 payload — the
    weight crosses the memory bus as 1 byte/element and is upconverted
    in-register — then scales the fp32 accumulator per channel.  The
    pallas backends run the fused dequant-matmul kernel.
    """
    if qt.q.ndim != 2:
        raise ValueError(
            f"qdot needs a sliced 2D weight, got {qt.shape} "
            "(apply decode_view / scan slicing first)")
    if qt.backend in ("pallas", "pallas_interpret"):
        from repro.kernels import qmatmul as _qm
        x2 = x.reshape(-1, x.shape[-1])
        y = _qm.qmatmul(x2, qt.q, qt.scale,
                        interpret=(qt.backend == "pallas_interpret"))
        return y.reshape(x.shape[:-1] + (qt.q.shape[-1],))
    y = jax.lax.dot_general(
        x, qt.q, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return y * qt.scale.reshape(-1)
