"""Parameter specs: shape + init + *logical* sharding axes, declared once.

A model's parameters are a nested dict of ``ParamSpec``; the same spec tree
serves four uses:

* ``init_params``      — materialize arrays (CPU smoke tests / real training)
* ``abstract_params``  — ``ShapeDtypeStruct`` stand-ins (the multi-pod dry-run
                         lowers against these; nothing is allocated)
* logical axes         — consumed by ``distributed/sharding.py`` which maps
                         logical names ("vocab", "embed", "mlp", ...) to mesh
                         axes with divisibility-aware fallback
* stacking             — ``stack_specs`` prepends a "layers" axis for
                         scan-over-layers models
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | small_normal
    scale: Optional[float] = None  # stddev; default fan-in
    dtype: Optional[Any] = None    # override the model param dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_one(spec: ParamSpec, key, default_dtype) -> Array:
    dtype = spec.dtype or default_dtype
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    fan_in = spec.shape[0] if len(spec.shape) >= 2 else max(spec.shape[-1], 1)
    std = spec.scale if spec.scale is not None else 1.0 / math.sqrt(fan_in)
    if spec.init == "small_normal":
        std = 0.02
    return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dtype)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(specs: PyTree, rng: Array, default_dtype=jnp.bfloat16) -> PyTree:
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(rng, len(leaves))
    out = [_init_one(s, k, default_dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)


def abstract_params(specs: PyTree, default_dtype=jnp.bfloat16) -> PyTree:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype or default_dtype),
        specs, is_leaf=is_spec)


def logical_axes(specs: PyTree) -> PyTree:
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


def stack_specs(specs: PyTree, n: int, axis_name: Optional[str] = "layers"
                ) -> PyTree:
    """Prepend a stacked-layers dimension to every spec (scan-over-layers)."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, (axis_name,) + s.axes,
                            init=s.init, scale=s.scale, dtype=s.dtype),
        specs, is_leaf=is_spec)


def restack_layers(per_layer: Dict[str, PyTree]) -> PyTree:
    """Restack a per-layer ``{"0": tree, "1": tree, ...}`` dict into the
    scan-over-layers layout (leading layer axis on every leaf).

    This is the bridge from per-layer-dispatch checkpoints (or the
    pre-refactor decode path) onto the stacked ``jax.lax.scan`` trunk:
    ``params["layers"] = restack_layers(params["layers"])`` and the same
    model serves under ``scan_layers=True``.
    """
    n = len(per_layer)
    trees = [per_layer[str(i)] for i in range(n)]
    return jax.tree.map(lambda *ls: jnp.stack(ls), *trees)


def count_params(specs: PyTree) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=is_spec)
    return sum(int(np.prod(s.shape)) for s in leaves)


def param_bytes(specs: PyTree, default_dtype=jnp.bfloat16) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=is_spec)
    return sum(int(np.prod(s.shape)) *
               jnp.dtype(s.dtype or default_dtype).itemsize for s in leaves)
