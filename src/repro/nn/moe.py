"""Mixture-of-Experts layer with static-capacity scatter/gather dispatch.

Expert-parallel design: expert weight tensors carry a leading logical
"expert" axis which the sharding rules map onto the mesh (``model`` when the
expert count divides it, else ``pod``/replicated — divisibility-aware
fallback in ``distributed/sharding.py``).  Dispatch is scatter-add into a
static (E, C, D) buffer, batched expert GEMMs (dot_general with the expert
batch dim sharded = expert parallelism; XLA inserts the all-to-all), then a
gather back.  Static shapes everywhere (paper Step-1 discipline): capacity
``C = ceil(T * k / E * capacity_factor)``, overflow tokens drop (standard
GShard semantics).

The router's position-in-expert computation is a cumulative sum over the
token axis — on the NPU this is exactly the class of op CumBA remaps;
we route it through ``core.segsum.cumsum`` so the XAMBA mode applies.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import pwl
from repro.nn import layers
from repro.nn.params import ParamSpec

Array = jax.Array


def moe_specs(cfg) -> dict:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    return {
        "router": {"w": ParamSpec((d, e), ("embed", None), scale=0.02)},
        "wi": ParamSpec((e, d, f), ("expert", "embed", "mlp")),
        "wg": ParamSpec((e, d, f), ("expert", "embed", "mlp")),
        "wo": ParamSpec((e, f, d), ("expert", "mlp", "embed")),
    }


def capacity(n_tokens: int, cfg) -> int:
    c = math.ceil(n_tokens * cfg.n_experts_per_token / cfg.n_experts
                  * cfg.capacity_factor)
    return max(8, int(math.ceil(c / 8) * 8))


def apply(params: dict, cfg, x: Array) -> Tuple[Array, Array]:
    """x: (b, s, d) -> (y, aux_loss)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.n_experts_per_token
    n = b * s
    cap = capacity(n, cfg)
    xf = x.reshape(n, d)

    logits = jnp.dot(xf.astype(jnp.float32), params["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                   # (n, e)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)           # (n, k)
    if cfg.moe_renormalize:
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Load-balancing auxiliary loss (Switch-style).
    me = jnp.mean(probs, axis=0)                              # (e,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_ids, e, dtype=jnp.float32), axis=1),
        axis=0)
    aux = jnp.sum(me * ce) * e

    # Position of each (token, slot) within its expert: a prefix sum over the
    # token axis.  This is exactly the op class CumBA remaps (see
    # core/segsum.py); at dispatch sizes (tokens*k can be millions) we use
    # the log-depth associative form — the CumBA triangular matmul is used
    # by the SSD path where the (T, T) working set fits on the MXU.
    onehot = jax.nn.one_hot(expert_ids, e, dtype=jnp.float32)  # (n, k, e)
    flat = onehot.reshape(n * k, e)
    pos = jax.lax.associative_scan(jnp.add, flat, axis=0)      # inclusive
    pos = (pos - 1.0) * flat                                   # 0-based
    pos_id = jnp.sum(pos.reshape(n, k, e), axis=-1)            # (n, k)
    keep = pos_id < cap
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    # Scatter tokens into (e, cap, d).  Under a distributed layout the
    # capacity dim is pinned to the batch axes so the expert buffers (and
    # the batched GEMMs below) stay sharded instead of XLA gathering the
    # full (e, cap, d) onto every device for the scatter/gather pair.
    from repro.distributed import api as dist_api
    eid = expert_ids.reshape(-1)
    pid = jnp.clip(pos_id.reshape(-1).astype(jnp.int32), 0, cap - 1)
    keep_f = keep.reshape(-1)
    src = jnp.repeat(xf, k, axis=0) * keep_f[:, None].astype(xf.dtype)
    buf = jnp.zeros((e, cap, d), xf.dtype)
    buf = buf.at[eid, pid].add(src, mode="drop")
    if cfg.moe_cap_batch_sharding:
        buf = dist_api.constrain_dims(buf, {1: "batch"})

    # Batched expert GEMMs (expert dim = EP sharding axis).
    act = pwl.activation("silu" if cfg.mlp_type == "swiglu" else "gelu",
                         cfg.xamba)
    hi = jnp.einsum("ecd,edf->ecf", buf, params["wi"],
                    preferred_element_type=jnp.float32)
    hg = jnp.einsum("ecd,edf->ecf", buf, params["wg"],
                    preferred_element_type=jnp.float32)
    h = (act(hg) * hi).astype(xf.dtype)
    out = jnp.einsum("ecf,efd->ecd", h, params["wo"],
                     preferred_element_type=jnp.float32).astype(xf.dtype)
    if cfg.moe_cap_batch_sharding:
        out = dist_api.constrain_dims(out, {1: "batch"})

    # Gather back and combine with gates.
    gathered = out[eid, pid]                                   # (n*k, d)
    if cfg.moe_cap_batch_sharding:
        gathered = dist_api.constrain_dims(gathered, {0: "batch"})
    gathered = gathered * (gate_vals.reshape(-1, 1).astype(xf.dtype) *
                           keep_f[:, None].astype(xf.dtype))
    y = jnp.sum(gathered.reshape(n, k, d), axis=1)
    return y.reshape(b, s, d), aux.astype(jnp.float32)
