from repro.nn import attention, layers, mlp, moe, params, ssm  # noqa: F401
