"""SSM mixer blocks: Mamba-2 (SSD), Mamba-1 (selective scan), RG-LRU.

These are the layers the paper actually profiles.  Every sequential op the
NPU chokes on is mode-switched through XambaConfig:

* SSD's segsum/cumsum           -> CumBA        (``core/segsum.py``)
* SSD's einsum contractions     -> ReduBA       (``core/reduce.py``)
* SiLU gates / Softplus(dt)     -> ActiBA       (``core/pwl.py``)
* fused intra-chunk kernel      -> ``kernels/ssd_chunk.py`` (pallas modes)

Each mixer exposes (specs, apply, init_state); ``apply`` handles both
full-sequence (train/prefill) and single-token (decode) paths with the same
parameters — the paper's Step-1 two-model enablement.  Passing ``state``
with a multi-token ``x`` resumes mid-prompt: the conv tail and SSM/LRU
state thread through, so feeding a prompt in slices equals one
whole-sequence call — this is what the serve engines' chunked prefill
leans on (``models/base.py: DecodeAPI.prefill_chunk``).
"""
from __future__ import annotations

import logging
import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pwl, selective_scan as sscan, ssd as ssd_mod
from repro.kernels.common import RG_LRU_C as _RG_C
from repro.nn import layers, quant
from repro.nn.params import ParamSpec

Array = jax.Array

log = logging.getLogger("repro.ssm")


# ============================================================================
# Mamba-2 mixer (SSD)
# ============================================================================

class Mamba2State(NamedTuple):
    conv: Array   # (b, d_conv-1, d_conv_dim)
    ssm: Array    # (b, nheads, headdim, d_state)


def mamba2_specs(cfg) -> dict:
    d = cfg.d_model
    d_inner = cfg.expand * d
    nheads = d_inner // cfg.ssm_head_dim
    g, n = cfg.ssm_ngroups, cfg.d_state
    d_xbc = d_inner + 2 * g * n
    d_in_proj = 2 * d_inner + 2 * g * n + nheads
    return {
        "in_proj": layers.linear_specs(d, d_in_proj, axes=("embed", "mlp")),
        "conv": layers.conv1d_specs(d_xbc, cfg.d_conv),
        "dt_bias": ParamSpec((nheads,), (None,), init="zeros"),
        "A_log": ParamSpec((nheads,), (None,), init="ones"),
        "D": ParamSpec((nheads,), (None,), init="ones"),
        "norm": layers.norm_specs(d_inner),
        "out_proj": layers.linear_specs(d_inner, d, axes=("mlp", "embed")),
    }


def mamba2_dims(cfg):
    d_inner = cfg.expand * cfg.d_model
    nheads = d_inner // cfg.ssm_head_dim
    return d_inner, nheads, cfg.ssm_ngroups, cfg.d_state


def mamba2_init_state(cfg, batch: int, dtype=jnp.float32) -> Mamba2State:
    d_inner, nheads, g, n = mamba2_dims(cfg)
    d_xbc = d_inner + 2 * g * n
    return Mamba2State(
        conv=jnp.zeros((batch, cfg.d_conv - 1, d_xbc), dtype),
        ssm=jnp.zeros((batch, nheads, cfg.ssm_head_dim, n), jnp.float32))


def _mamba2_decode_naive(params: dict, cfg, x: Array, state: Mamba2State
                         ) -> Tuple[Array, Mamba2State]:
    """The unfused dense step (the pre-refactor / NPU-baseline op chain):
    seq-axis (b, 1, d) operands end to end, per-tap conv slices, and the
    state contraction as broadcast-multiply + ReduceSum."""
    b, l, d = x.shape
    d_inner, nheads, g, n = mamba2_dims(cfg)
    p_hd = cfg.ssm_head_dim
    xamba = cfg.xamba
    silu = pwl.activation("silu", xamba)
    softplus = pwl.activation("softplus", xamba)

    zxbcdt = layers.linear(params["in_proj"], x)
    z, xbc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner + 2 * g * n], axis=-1)
    xbc_conv, new_conv = layers.causal_conv1d(params["conv"], xbc,
                                              state.conv)
    xbc_conv = silu(xbc_conv)
    xs, B, C = jnp.split(xbc_conv, [d_inner, d_inner + g * n], axis=-1)
    xs = xs.reshape(b, l, nheads, p_hd)
    dt = softplus(dt.astype(jnp.float32) +
                  params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    new_ssm, y = ssd_mod.ssd_decode_step(
        state.ssm, xs[:, 0], dt[:, 0], A, B.reshape(b, g, n),
        C.reshape(b, g, n), mode="naive")
    y = y[:, None] + xs * params["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(b, l, d_inner)
    y = layers.norm(params["norm"], y) * silu(z)
    out = layers.linear(params["out_proj"], y.astype(x.dtype))
    return out, Mamba2State(new_conv, new_ssm)


def _mamba2_decode(params: dict, cfg, x: Array, state: Mamba2State
                   ) -> Tuple[Array, Mamba2State]:
    """Fused single-token step, dispatched on ``XambaConfig.decode``."""
    b = x.shape[0]
    d_inner, nheads, g, n = mamba2_dims(cfg)
    p_hd = cfg.ssm_head_dim
    xamba = cfg.xamba
    mode = xamba.decode
    if mode == "naive":
        return _mamba2_decode_naive(params, cfg, x, state)

    # Token-major 2D layout throughout: (b, 1, d) batched matmuls hit a
    # slow XLA-CPU gemm path; the whole step runs on (b, d) operands.
    zxbcdt = layers.linear(params["in_proj"], x[:, 0])       # (b, d_in_proj)
    z, xbc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner + 2 * g * n], axis=-1)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))        # (nheads,)

    if mode in ("pallas", "pallas_interpret"):
        from repro.kernels import ops as kops
        y, new_conv, new_ssm = kops.mamba2_decode_step(
            z, xbc, dt, state.conv, state.ssm,
            params["conv"]["w"], params["conv"]["b"], params["dt_bias"],
            A, params["D"], params["norm"]["scale"],
            ngroups=g, head_dim=p_hd, xamba=xamba,
            interpret=(mode == "pallas_interpret"))
    else:
        silu = pwl.activation("silu", xamba)
        softplus = pwl.activation("softplus", xamba)
        xbc_conv, new_conv = layers.causal_conv1d_step(
            params["conv"], xbc, state.conv)
        xbc_conv = silu(xbc_conv)
        xs, B, C = jnp.split(xbc_conv, [d_inner, d_inner + g * n], axis=-1)
        xs = xs.reshape(b, nheads, p_hd)
        dt_f = softplus(dt.astype(jnp.float32) +
                        params["dt_bias"].astype(jnp.float32))
        new_ssm, y = ssd_mod.ssd_decode_step(
            state.ssm, xs, dt_f, A, B.reshape(b, g, n), C.reshape(b, g, n),
            mode=mode)
        y = y + xs * params["D"].astype(x.dtype)[None, :, None]
        y = layers.norm(params["norm"], y.reshape(b, d_inner)) * silu(z)
    out = layers.linear(params["out_proj"], y.astype(x.dtype))[:, None]
    return out, Mamba2State(new_conv, new_ssm)


def mamba2_apply(params: dict, cfg, x: Array,
                 state: Optional[Mamba2State] = None,
                 ) -> Tuple[Array, Optional[Mamba2State]]:
    """x: (b, l, d). l==1 + state -> decode step; else full sequence."""
    b, l, d = x.shape
    d_inner, nheads, g, n = mamba2_dims(cfg)
    p_hd = cfg.ssm_head_dim
    xamba = cfg.xamba

    if state is not None and l == 1 and not cfg.force_prefill_path:
        return _mamba2_decode(params, cfg, x, state)

    pf_mode = xamba.prefill
    if pf_mode != "naive":
        # Trace-time eligibility gate: the fused pipeline takes RAW dt and
        # the live conv tail, so it cannot hide ineligible shapes behind
        # dt=0 padding the way ``core/ssd.py`` does — it requires exact
        # chunking and falls back to the unfused chain otherwise.
        chunk = min(cfg.chunk_size, l)
        reason = None
        if cfg.ssd_dtype != "float32":
            reason = f"ssd_dtype={cfg.ssd_dtype} (fused prefill is fp32-only)"
        elif l % chunk:
            reason = f"seqlen {l} not a multiple of chunk {chunk}"
        elif pf_mode == "pallas" and chunk % 64:
            reason = f"chunk {chunk} not a multiple of 64 (MXU tiling)"
        if reason is None:
            from repro.kernels import ops as kops
            if state is not None:
                conv_state, init = state.conv, state.ssm
            else:
                d_xbc = d_inner + 2 * g * n
                conv_state = jnp.zeros((b, cfg.d_conv - 1, d_xbc), x.dtype)
                init = jnp.zeros((b, nheads, p_hd, n), jnp.float32)
            A = -jnp.exp(params["A_log"].astype(jnp.float32))
            y, new_conv, new_ssm = kops.mamba2_prefill(
                x, params["in_proj"]["w"], conv_state, init,
                params["conv"]["w"], params["conv"]["b"],
                params["dt_bias"], A, params["D"], params["norm"]["scale"],
                ngroups=g, head_dim=p_hd, chunk=chunk, xamba=xamba,
                mode=pf_mode)
            out = layers.linear(params["out_proj"], y.astype(x.dtype))
            new_state = (Mamba2State(new_conv, new_ssm)
                         if state is not None else None)
            return out, new_state
        # Fires once per compiled shape (trace-time), not per call.
        log.info("fused prefill (%s) skipped: %s — running the unfused "
                 "chain", pf_mode, reason)

    silu = pwl.activation("silu", xamba)
    softplus = pwl.activation("softplus", xamba)

    zxbcdt = layers.linear(params["in_proj"], x)
    z, xbc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner + 2 * g * n], axis=-1)

    conv_state = state.conv if state is not None else None
    xbc_conv, new_conv = layers.causal_conv1d(params["conv"], xbc, conv_state)
    xbc_conv = silu(xbc_conv)
    xs, B, C = jnp.split(xbc_conv, [d_inner, d_inner + g * n], axis=-1)
    xs = xs.reshape(b, l, nheads, p_hd)
    B = B.reshape(b, l, g, n)
    C = C.reshape(b, l, g, n)
    dt = softplus(dt.astype(jnp.float32) +
                  params["dt_bias"].astype(jnp.float32))     # (b, l, nheads)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))        # (nheads,)

    init = state.ssm if state is not None else None
    mm_dtype = jnp.bfloat16 if cfg.ssd_dtype == "bfloat16" else None
    y, new_ssm = ssd_mod.ssd(
        xs, dt, A, B, C, chunk_size=min(cfg.chunk_size, l),
        initial_state=init, xamba=xamba, return_final_state=True,
        matmul_dtype=mm_dtype)

    y = y + xs * params["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(b, l, d_inner)
    y = layers.norm(params["norm"], y) * silu(z)
    out = layers.linear(params["out_proj"], y.astype(x.dtype))
    new_state = Mamba2State(new_conv, new_ssm) if state is not None else None
    return out, new_state


# ============================================================================
# Mamba-1 mixer (selective scan)
# ============================================================================

class Mamba1State(NamedTuple):
    conv: Array  # (b, d_conv-1, d_inner)
    ssm: Array   # (b, d_inner, d_state)


def mamba1_specs(cfg) -> dict:
    d = cfg.d_model
    d_inner = cfg.expand * d
    n = cfg.d_state
    dt_rank = cfg.dt_rank or math.ceil(d / 16)
    return {
        "in_proj": layers.linear_specs(d, 2 * d_inner, axes=("embed", "mlp")),
        "conv": layers.conv1d_specs(d_inner, cfg.d_conv),
        "x_proj": layers.linear_specs(d_inner, dt_rank + 2 * n,
                                      axes=("mlp", None)),
        "dt_proj": {
            "w": ParamSpec((dt_rank, d_inner), (None, "mlp"), scale=0.1),
            "b": ParamSpec((d_inner,), ("mlp",), init="small_normal"),
        },
        "A_log": ParamSpec((d_inner, n), ("mlp", None), init="ones"),
        "D": ParamSpec((d_inner,), ("mlp",), init="ones"),
        "out_proj": layers.linear_specs(d_inner, d, axes=("mlp", "embed")),
    }


def mamba1_init_state(cfg, batch: int, dtype=jnp.float32) -> Mamba1State:
    d_inner = cfg.expand * cfg.d_model
    return Mamba1State(
        conv=jnp.zeros((batch, cfg.d_conv - 1, d_inner), dtype),
        ssm=jnp.zeros((batch, d_inner, cfg.d_state), jnp.float32))


def _mamba1_decode_naive(params: dict, cfg, x: Array, state: Mamba1State
                         ) -> Tuple[Array, Mamba1State]:
    """The unfused dense step (pre-refactor / NPU-baseline op chain)."""
    b, l, d = x.shape
    n = cfg.d_state
    dt_rank = cfg.dt_rank or math.ceil(d / 16)
    xamba = cfg.xamba
    silu = pwl.activation("silu", xamba)
    softplus = pwl.activation("softplus", xamba)

    xz = layers.linear(params["in_proj"], x)
    xs, z = jnp.split(xz, 2, axis=-1)
    xs, new_conv = layers.causal_conv1d(params["conv"], xs, state.conv)
    xs = silu(xs)
    dbc = layers.linear(params["x_proj"], xs)
    dt, B, C = jnp.split(dbc, [dt_rank, dt_rank + n], axis=-1)
    dt = jnp.dot(dt, params["dt_proj"]["w"].astype(dt.dtype)) + \
        params["dt_proj"]["b"].astype(dt.dtype)
    dt = softplus(dt.astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    new_ssm, y = sscan.selective_scan_decode_step(
        state.ssm, xs[:, 0], dt[:, 0], A, B[:, 0], C[:, 0], params["D"],
        mode="naive")
    y = y[:, None] * silu(z)
    out = layers.linear(params["out_proj"], y.astype(x.dtype))
    return out, Mamba1State(new_conv, new_ssm)


def _mamba1_decode(params: dict, cfg, x: Array, state: Mamba1State
                   ) -> Tuple[Array, Mamba1State]:
    """Fused single-token step, dispatched on ``XambaConfig.decode``."""
    n = cfg.d_state
    dt_rank = cfg.dt_rank or math.ceil(x.shape[-1] / 16)
    xamba = cfg.xamba
    mode = xamba.decode
    if mode == "naive":
        return _mamba1_decode_naive(params, cfg, x, state)

    xz = layers.linear(params["in_proj"], x[:, 0])           # (b, 2*d_inner)
    xs_raw, z = jnp.split(xz, 2, axis=-1)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))        # (d_inner, n)

    if mode in ("pallas", "pallas_interpret"):
        from repro.kernels import ops as kops
        y, new_conv, new_ssm = kops.mamba1_decode_step(
            xs_raw, z, state.conv, state.ssm,
            params["conv"]["w"], params["conv"]["b"],
            params["x_proj"]["w"], params["dt_proj"]["w"],
            params["dt_proj"]["b"], A, params["D"],
            dt_rank=dt_rank, xamba=xamba,
            interpret=(mode == "pallas_interpret"))
    else:
        silu = pwl.activation("silu", xamba)
        softplus = pwl.activation("softplus", xamba)
        xs, new_conv = layers.causal_conv1d_step(
            params["conv"], xs_raw, state.conv)
        xs = silu(xs)
        dbc = layers.linear(params["x_proj"], xs)
        dt, B, C = jnp.split(dbc, [dt_rank, dt_rank + n], axis=-1)
        dt = jnp.dot(dt, params["dt_proj"]["w"].astype(dt.dtype)) + \
            params["dt_proj"]["b"].astype(dt.dtype)
        dt = softplus(dt.astype(jnp.float32))                # (b, d_inner)
        new_ssm, y = sscan.selective_scan_decode_step(
            state.ssm, xs, dt, A, B, C, params["D"], mode=mode)
        y = y * silu(z)
    out = layers.linear(params["out_proj"], y.astype(x.dtype))[:, None]
    return out, Mamba1State(new_conv, new_ssm)


def mamba1_apply(params: dict, cfg, x: Array,
                 state: Optional[Mamba1State] = None,
                 ) -> Tuple[Array, Optional[Mamba1State]]:
    b, l, d = x.shape
    d_inner = cfg.expand * d
    n = cfg.d_state
    dt_rank = cfg.dt_rank or math.ceil(d / 16)
    xamba = cfg.xamba

    if state is not None and l == 1 and not cfg.force_prefill_path:
        return _mamba1_decode(params, cfg, x, state)

    silu = pwl.activation("silu", xamba)
    softplus = pwl.activation("softplus", xamba)

    xz = layers.linear(params["in_proj"], x)
    xs, z = jnp.split(xz, 2, axis=-1)

    conv_state = state.conv if state is not None else None
    xs, new_conv = layers.causal_conv1d(params["conv"], xs, conv_state)
    xs = silu(xs)

    dbc = layers.linear(params["x_proj"], xs)
    dt, B, C = jnp.split(dbc, [dt_rank, dt_rank + n], axis=-1)
    dt = jnp.dot(dt, params["dt_proj"]["w"].astype(dt.dtype)) + \
        params["dt_proj"]["b"].astype(dt.dtype)
    dt = softplus(dt.astype(jnp.float32))                    # (b, l, d_inner)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))        # (d_inner, n)
    D = params["D"]

    init = state.ssm if state is not None else None
    y, new_ssm = sscan.selective_scan(
        xs, dt, A, B, C, D, mode=cfg.scan_mode, initial_state=init,
        xamba=xamba, return_final_state=True)

    y = y * silu(z)
    out = layers.linear(params["out_proj"], y.astype(x.dtype))
    new_state = Mamba1State(new_conv, new_ssm) if state is not None else None
    return out, new_state


# ============================================================================
# RG-LRU recurrent block (recurrentgemma / Griffin)
# ============================================================================

class RGLRUState(NamedTuple):
    conv: Array  # (b, d_conv-1, lru_width)
    h: Array     # (b, lru_width)


def rglru_specs(cfg) -> dict:
    d, w = cfg.d_model, cfg.lru_width
    return {
        "in_x": layers.linear_specs(d, w, axes=("embed", "mlp")),
        "in_gate": layers.linear_specs(d, w, axes=("embed", "mlp")),
        "conv": layers.conv1d_specs(w, cfg.d_conv),
        "rg": layers.linear_specs(w, w, axes=("mlp", "mlp2"), bias=True),
        "ig": layers.linear_specs(w, w, axes=("mlp", "mlp2"), bias=True),
        "lam": ParamSpec((w,), ("mlp",), init="ones", scale=1.0),
        "out": layers.linear_specs(w, d, axes=("mlp", "embed")),
    }


def rglru_init_state(cfg, batch: int, dtype=jnp.float32) -> RGLRUState:
    return RGLRUState(
        conv=jnp.zeros((batch, cfg.d_conv - 1, cfg.lru_width), dtype),
        h=jnp.zeros((batch, cfg.lru_width), jnp.float32))


def _rglru_decode_naive(params: dict, cfg, x: Array, state: RGLRUState
                        ) -> Tuple[Array, RGLRUState]:
    """The unfused dense step (pre-refactor / NPU-baseline op chain)."""
    xamba = cfg.xamba
    sigmoid = pwl.activation("sigmoid", xamba)
    softplus = pwl.activation("softplus", xamba)
    gelu = pwl.activation("gelu", xamba)

    u = layers.linear(params["in_x"], x)                     # (b, 1, w)
    gate = layers.linear(params["in_gate"], x)
    u, new_conv = layers.causal_conv1d(params["conv"], u, state.conv)
    r = sigmoid(layers.linear(params["rg"], u).astype(jnp.float32))
    i = sigmoid(layers.linear(params["ig"], u).astype(jnp.float32))
    log_a = -_RG_C * softplus(params["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * \
        (i * u.astype(jnp.float32))
    h_new = a[:, 0] * state.h + gated_in[:, 0]
    y = h_new[:, None].astype(x.dtype) * gelu(gate)
    out = layers.linear(params["out"], y)
    return out, RGLRUState(new_conv, h_new)


def _rglru_decode(params: dict, cfg, x: Array, state: RGLRUState
                  ) -> Tuple[Array, RGLRUState]:
    """Fused single-token step, dispatched on ``XambaConfig.decode``."""
    xamba = cfg.xamba
    mode = xamba.decode
    if mode == "naive":
        return _rglru_decode_naive(params, cfg, x, state)

    u = layers.linear(params["in_x"], x[:, 0])               # (b, w)
    gate = layers.linear(params["in_gate"], x[:, 0])

    if mode in ("pallas", "pallas_interpret"):
        from repro.kernels import ops as kops
        # The fused step kernel takes raw fp gate weights; under W8 the
        # rg/ig projections dequantize in-program here, which
        # MATERIALIZES an fp32 copy per step (pallas_call operands are
        # concrete) — correctness-first: this path keeps the storage win
        # but not the bandwidth win until the kernel ingests int8+scale
        # tiles like kernels/qmatmul.py does.
        y, new_conv, h_new = kops.rglru_decode_step(
            u, gate, state.conv, state.h,
            params["conv"]["w"], params["conv"]["b"],
            quant.maybe_dequant(params["rg"]["w"]), params["rg"]["b"],
            quant.maybe_dequant(params["ig"]["w"]), params["ig"]["b"],
            params["lam"],
            xamba=xamba, interpret=(mode == "pallas_interpret"))
        y = y.astype(x.dtype)
    else:
        sigmoid = pwl.activation("sigmoid", xamba)
        softplus = pwl.activation("softplus", xamba)
        gelu = pwl.activation("gelu", xamba)
        u, new_conv = layers.causal_conv1d_step(params["conv"], u, state.conv)
        r = sigmoid(layers.linear(params["rg"], u).astype(jnp.float32))
        i = sigmoid(layers.linear(params["ig"], u).astype(jnp.float32))
        log_a = -_RG_C * softplus(params["lam"].astype(jnp.float32)) * r
        a = jnp.exp(log_a)
        gated_in = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
            * (i * u.astype(jnp.float32))
        h_new = a * state.h + gated_in
        y = h_new.astype(x.dtype) * gelu(gate)
    out = layers.linear(params["out"], y)[:, None]
    return out, RGLRUState(new_conv, h_new)


def rglru_apply(params: dict, cfg, x: Array,
                state: Optional[RGLRUState] = None,
                ) -> Tuple[Array, Optional[RGLRUState]]:
    b, l, d = x.shape
    xamba = cfg.xamba

    if state is not None and l == 1 and not cfg.force_prefill_path:
        return _rglru_decode(params, cfg, x, state)

    sigmoid = pwl.activation("sigmoid", xamba)
    softplus = pwl.activation("softplus", xamba)
    gelu = pwl.activation("gelu", xamba)

    u = layers.linear(params["in_x"], x)                     # (b, l, w)
    gate = layers.linear(params["in_gate"], x)

    conv_state = state.conv if state is not None else None
    u, new_conv = layers.causal_conv1d(params["conv"], u, conv_state)

    r = sigmoid(layers.linear(params["rg"], u).astype(jnp.float32))
    i = sigmoid(layers.linear(params["ig"], u).astype(jnp.float32))
    log_a = -_RG_C * softplus(params["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * \
        (i * u.astype(jnp.float32))

    if xamba.cumba in ("pallas", "pallas_interpret") and state is None:
        from repro.kernels import ops as kops
        h = kops.rg_lru_scan(
            a, gated_in, interpret=(xamba.cumba == "pallas_interpret"))
    else:
        def comb(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, b1 * a2 + b2
        a_sc, h_sc = jax.lax.associative_scan(comb, (a, gated_in), axis=1)
        h0 = state.h if state is not None else jnp.zeros(
            (b, cfg.lru_width), jnp.float32)
        h = h_sc + a_sc * h0[:, None]
    h_new = h[:, -1]

    y = h.astype(x.dtype) * gelu(gate)
    out = layers.linear(params["out"], y)
    new_state = RGLRUState(new_conv, h_new) if state is not None else None
    return out, new_state
