from repro.optim.adamw import AdamWConfig, init, update, global_norm  # noqa: F401
from repro.optim.schedule import ScheduleConfig, lr_at  # noqa: F401
