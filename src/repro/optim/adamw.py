"""AdamW with dtype policy — self-contained (no optax offline).

Moment dtypes are configurable so very large archs (grok-1 at 314B params)
can hold m/v in bf16 and stay inside v5e HBM; the ZeRO-style sharding comes
for free because optimizer state inherits each param's NamedSharding.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    m_dtype: Optional[str] = "float32"
    v_dtype: Optional[str] = "float32"


def init(params: PyTree, cfg: AdamWConfig) -> dict:
    def zeros_like(p, dtype):
        return jnp.zeros(p.shape, jnp.dtype(dtype) if dtype else p.dtype)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(lambda p: zeros_like(p, cfg.m_dtype), params),
        "v": jax.tree.map(lambda p: zeros_like(p, cfg.v_dtype), params),
    }


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(grads: PyTree, state: dict, params: PyTree, lr: jax.Array,
           cfg: AdamWConfig) -> Tuple[PyTree, dict, dict]:
    """Returns (new_params, new_state, stats)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip else jnp.float32(1.0)
    step = state["step"] + 1
    c1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + g * (1 - cfg.b1)
        v32 = v.astype(jnp.float32) * cfg.b2 + jnp.square(g) * (1 - cfg.b2)
        mhat = m32 / c1
        vhat = v32 / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"step": step, "m": new_m, "v": new_v}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
