#!/usr/bin/env python
"""Docs link check: every relative markdown link in README.md and docs/
must resolve to a real file (anchors and external URLs are skipped), and
every page under docs/ must be *reachable* — linked from README.md or
another doc — so new pages cannot silently ship orphaned.

    python scripts/check_doc_links.py          # from the repo root
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def check(root: Path) -> int:
    failures = 0
    sources = [root / "README.md", *sorted((root / "docs").glob("*.md"))]
    linked: set = set()
    for src in sources:
        if not src.exists():
            print(f"MISSING SOURCE {src}")
            failures += 1
            continue
        for lineno, line in enumerate(src.read_text().splitlines(), 1):
            for target in LINK_RE.findall(line):
                if target.startswith(SKIP_PREFIXES):
                    continue
                path = target.split("#", 1)[0]
                if not path:
                    continue
                resolved = (src.parent / path).resolve()
                if not resolved.exists():
                    print(f"{src.relative_to(root)}:{lineno}: "
                          f"broken link -> {target}")
                    failures += 1
                elif src != resolved:
                    linked.add(resolved)
    for page in sorted((root / "docs").glob("*.md")):
        if page.resolve() not in linked:
            print(f"{page.relative_to(root)}: orphan page — not linked "
                  "from README.md or any other doc")
            failures += 1
    print(f"checked {len(sources)} files: "
          f"{'OK' if not failures else f'{failures} problem(s)'}")
    return failures


if __name__ == "__main__":
    sys.exit(1 if check(Path(__file__).resolve().parent.parent) else 0)
