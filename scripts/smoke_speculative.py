"""CI smoke for self-speculative decoding (``ServeConfig.speculate_k``).

Runs the same continuous-serve workload twice on a reduced fp32 mamba2 —
once plain, once with ``speculate_k`` bursts (w8 draft + full-precision
batched verify + snapshot rollback) — and asserts speculation is
observably invisible except for the burst metrics:

* greedy outputs byte-identical per request, spec on vs off;
* the drafts were actually useful: ``spec_accept_rate > 0``;
* compile-once discipline holds: the draft pass is a second trace of the
  ONE decode program, verify is one program, and after a warmup +
  ``reset_stats()`` round zero recompile sentinels trip.

Exits nonzero on any violation (``make smoke-spec``).
"""
import sys

import jax
import numpy as np

sys.path.insert(0, "src")

from repro.configs import get_config               # noqa: E402
from repro.models import build_model               # noqa: E402
from repro.nn.params import init_params            # noqa: E402
from repro.serve import ContinuousEngine, ServeConfig  # noqa: E402


def _submit_round(eng, rng, vocab, lengths):
    # Token ids must stay in-vocab: an out-of-range embedding gather
    # yields NaN logits, making every greedy comparison vacuous (argmax
    # of an all-NaN row is always index 0).
    for length in lengths:
        eng.submit(rng.integers(1, vocab, int(length)).tolist())
    return {r.uid: r.out_tokens for r in eng.run()}


def run(speculate_k: int):
    cfg = get_config("mamba2-130m", reduced=True).replace(
        param_dtype="float32")
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         cfg.dtype)
    eng = ContinuousEngine(model, params, ServeConfig(
        max_batch=2, prefill_buckets=(16, 32), max_new_tokens=6,
        speculate_k=speculate_k, strict_recompile=bool(speculate_k)))
    rng = np.random.default_rng(0)
    try:
        # Warmup must visit BOTH prefill buckets: any program shape first
        # seen after reset_stats() counts as a post-warmup retrace.
        warm = _submit_round(eng, rng, cfg.vocab_size, (6, 20, 10, 28))
        eng.reset_stats()
        post = _submit_round(eng, rng, cfg.vocab_size,
                             rng.integers(4, 30, 6))
    finally:
        eng.close()
    trips = {k: s.trips for k, s in eng.sentinels.items()}
    return {**warm, **post}, dict(eng.counters), \
        eng.metrics.summary(), trips


def main():
    base, _, _, _ = run(0)
    spec, counters, metrics, trips = run(4)

    assert set(base) == set(spec)
    for uid in base:
        assert spec[uid] == base[uid], (
            f"greedy divergence spec vs plain, uid={uid}: "
            f"{spec[uid]} != {base[uid]}")
    assert metrics["spec_bursts"] > 0, metrics
    assert metrics["spec_accept_rate"] > 0, metrics
    assert counters["decode_compiles"] == 2, counters   # fp + w8 trace
    assert counters["verify_compiles"] == 1, counters
    assert not any(trips.values()), f"post-warmup recompiles: {trips}"
    print(f"smoke-spec OK: {len(base)} requests greedy-identical "
          f"(speculate_k=4 vs off), accept_rate "
          f"{metrics['spec_accept_rate']:.3f}, tokens_per_verify "
          f"{metrics['spec_tokens_per_verify']:.2f}, trips={trips}, "
          f"counters={counters}")


if __name__ == "__main__":
    main()
