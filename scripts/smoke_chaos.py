"""CI chaos smoke for the fault-tolerant serve stack (docs/robustness.md).

Runs the same continuous-serve workload twice on a reduced fp32 mamba2
(decode mode ``cumba`` so the fallback ladder has a rung down) — once
fault-free, once under a seeded three-event chaos plan armed after
warmup:

* 1 ``poison``  — one slot's recurrent state NaN-corrupted; the logits
  probe must quarantine exactly that request;
* 1 ``stall``   — a 50 ms sleep inside one decode call's timing window;
* 1 ``fail``    — an injected backend failure at the decode boundary;
  the engine must fall back ``cumba -> naive`` and retry.

Asserts the blast radius: every *healthy* request's greedy output is
byte-identical to the fault-free run, the expected robustness counters
fired (1 quarantine, 1 backend fallback, all three plan events), and the
compile-once discipline survived the chaos — zero recompile-sentinel
trips after warmup (``strict_recompile`` would also have raised at the
offending call).  Exits nonzero on any violation (``make smoke-chaos``).
"""
import sys

import jax
import numpy as np

sys.path.insert(0, "src")

from repro.configs import get_config               # noqa: E402
from repro.models import build_model               # noqa: E402
from repro.nn.params import init_params            # noqa: E402
from repro.serve import ContinuousEngine, ServeConfig  # noqa: E402

LENGTHS = (6, 20, 10, 28, 14, 8)


def _submit_round(eng, rng, vocab, lengths):
    # Token ids MUST stay in-vocab: an out-of-range embedding gather
    # produces NaN logits, which the poison probe (correctly) quarantines.
    for length in lengths:
        eng.submit(rng.integers(1, vocab, int(length)).tolist())
    return {r.uid: r for r in eng.run()}


def run(chaos: bool):
    cfg = get_config("mamba2-130m", reduced=True).replace(
        param_dtype="float32").with_decode_mode("cumba")
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         cfg.dtype)
    eng = ContinuousEngine(model, params, ServeConfig(
        max_batch=2, prefill_buckets=(16, 32), max_new_tokens=8,
        poison_probe="logits", strict_recompile=True))
    rng = np.random.default_rng(0)
    try:
        # Warmup visits both prefill buckets; any program shape first seen
        # after reset_stats() would count as a post-warmup retrace.
        _submit_round(eng, rng, cfg.vocab_size, (6, 20, 10, 28))
        eng.reset_stats()
        if chaos:
            base = eng.poll_index
            eng.set_fault_plan(
                f"poison@{base + 2}:slot=0;"
                f"stall@{base + 4}:program=decode,stall_s=0.05;"
                f"fail@{base + 6}:program=decode")
        done = _submit_round(eng, rng, cfg.vocab_size, LENGTHS)
    finally:
        eng.close()
    trips = {k: s.trips for k, s in eng.sentinels.items()}
    return done, eng.metrics, trips, eng


def main():
    base, _, _, _ = run(chaos=False)
    done, metrics, trips, eng = run(chaos=True)

    assert set(base) == set(done), (sorted(base), sorted(done))
    poisoned = [r for r in done.values() if r.status == "poisoned"]
    healthy = [r for r in done.values() if r.status == "ok"]
    assert len(poisoned) == 1, [r.status for r in done.values()]
    assert len(healthy) == len(LENGTHS) - 1
    for r in healthy:
        assert r.out_tokens == base[r.uid].out_tokens, (
            f"healthy request {r.uid} diverged under chaos: "
            f"{r.out_tokens} != {base[r.uid].out_tokens}")

    fired = eng._injector.summary()["fired"]
    assert fired == {"poison": 1, "fail": 1, "stall": 1}, fired
    assert metrics.quarantined == 1, metrics.quarantined
    assert metrics.backend_fallbacks == 1, metrics.backend_fallbacks
    assert metrics.shed_reasons == {"poison": 1}, metrics.shed_reasons
    assert metrics.completed == len(LENGTHS) - 1, metrics.completed
    assert eng.model.cfg.xamba.decode == "naive", eng.model.cfg.xamba.decode
    assert not any(trips.values()), f"post-warmup recompiles: {trips}"
    print(f"smoke-chaos OK: {len(healthy)}/{len(LENGTHS)} healthy requests "
          f"greedy-identical under chaos (1 quarantined), fired={fired}, "
          f"fallback cumba->naive, trips={trips}")


if __name__ == "__main__":
    main()
