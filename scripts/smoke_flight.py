"""CI flight-recorder smoke (docs/observability.md): an injected fault
must auto-dump the request ring to JSONL, and ``trace_report --flight``
must parse and render it.

Same reduced fp32 mamba2 setup as ``smoke_chaos`` (decode mode ``cumba``
so the injected failure has a fallback rung), with the flight recorder
armed via ``ServeConfig.flight_records`` / ``flight_path``.  One warmup
round, then a seeded plan fires one poison (quarantine -> dump) and one
backend fail (retry + fallback -> dumps); asserts the JSONL contains the
fault headers and per-request ring entries, then shells the CLI reader
over it (``make smoke-flight``).
"""
import json
import os
import subprocess
import sys
import tempfile

import jax
import numpy as np

sys.path.insert(0, "src")

from repro.configs import get_config               # noqa: E402
from repro.models import build_model               # noqa: E402
from repro.nn.params import init_params            # noqa: E402
from repro.serve import ContinuousEngine, ServeConfig  # noqa: E402
from repro.serve.flight_recorder import load_flight    # noqa: E402

LENGTHS = (6, 20, 10, 28, 14, 8)


def _submit_round(eng, rng, vocab, lengths):
    for length in lengths:
        eng.submit(rng.integers(1, vocab, int(length)).tolist())
    return {r.uid: r for r in eng.run()}


def main():
    path = os.path.join(tempfile.mkdtemp(prefix="flight_"), "flight.jsonl")
    cfg = get_config("mamba2-130m", reduced=True).replace(
        param_dtype="float32").with_decode_mode("cumba")
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         cfg.dtype)
    eng = ContinuousEngine(model, params, ServeConfig(
        max_batch=2, prefill_buckets=(16, 32), max_new_tokens=8,
        poison_probe="logits", strict_recompile=True,
        flight_records=16, flight_path=path))
    rng = np.random.default_rng(0)
    try:
        _submit_round(eng, rng, cfg.vocab_size, (6, 20, 10, 28))
        eng.reset_stats()
        base = eng.poll_index
        eng.set_fault_plan(f"poison@{base + 2}:slot=0;"
                           f"fail@{base + 5}:program=decode")
        done = _submit_round(eng, rng, cfg.vocab_size, LENGTHS)
        dumps_emitted = eng.flight.dumps
        recorded = eng.flight.recorded
    finally:
        eng.close()

    assert len(done) == len(LENGTHS), len(done)
    assert os.path.exists(path), f"no flight dump at {path}"
    assert dumps_emitted >= 2, (
        f"expected dumps for quarantine AND backend fallback, "
        f"got {dumps_emitted}")
    assert recorded >= len(LENGTHS), recorded

    dumps = load_flight(path)
    assert len(dumps) == dumps_emitted, (len(dumps), dumps_emitted)
    kinds = [d["fault"]["kind"] for d in dumps]
    assert "quarantine" in kinds, kinds
    assert "backend_fallback" in kinds, kinds
    # The quarantine dump's ring must carry the poisoned request.
    qdump = dumps[kinds.index("quarantine")]
    statuses = {r["uid"]: r["status"] for r in qdump["requests"]}
    assert "poisoned" in statuses.values(), statuses

    # The CLI reader must parse and render the same file, and --json must
    # round-trip it.
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.trace_report",
         "--flight", path, "--check"],
        capture_output=True, text=True, env=env, cwd=os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stdout + out.stderr
    assert "quarantine" in out.stdout, out.stdout
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.trace_report",
         "--flight", path, "--json"],
        capture_output=True, text=True, env=env, cwd=os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stdout + out.stderr
    parsed = json.loads(out.stdout)
    assert len(parsed) == len(dumps), (len(parsed), len(dumps))

    print(f"smoke-flight OK: {dumps_emitted} fault dumps "
          f"({', '.join(kinds)}), {recorded} requests recorded, "
          f"CLI parsed {len(parsed)} dumps from {path}")


if __name__ == "__main__":
    main()
