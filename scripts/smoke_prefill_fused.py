"""CI smoke for the fused SSD prefill pipeline (``XambaConfig.prefill``).

Runs the same chunked continuous-serve workload twice on a reduced fp32
mamba2 — once on the unfused chain (``prefill="naive"``), once through
the one-kernel Pallas pipeline in interpret mode
(``prefill="pallas_interpret"``, the CPU-runnable CI backend) — and
asserts the fused backend is observably invisible:

* greedy outputs byte-identical per request, fused vs unfused;
* compile-once discipline holds under the fused backend: exactly one
  prefill_chunk program and one decode program, zero recompiles.

Exits nonzero on any violation (``make smoke-prefill-fused``).
"""
import sys

import jax
import numpy as np

sys.path.insert(0, "src")

from repro.configs import get_config               # noqa: E402
from repro.models import build_model               # noqa: E402
from repro.nn.params import init_params            # noqa: E402
from repro.serve import ContinuousEngine, ServeConfig  # noqa: E402


def run(prefill_mode: str, prompts):
    cfg = get_config("mamba2-130m", reduced=True).replace(
        param_dtype="float32").with_prefill_mode(prefill_mode)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         cfg.dtype)
    eng = ContinuousEngine(model, params, ServeConfig(
        max_batch=2, prefill_buckets=(16, 32), max_new_tokens=6,
        prefill_chunk=8))
    for p in prompts:
        eng.submit(p)
    out = {r.uid: r.out_tokens for r in eng.run()}
    return out, dict(eng.counters)


def main():
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, 4000, int(n)).tolist()
               for n in rng.integers(4, 30, 6)]
    naive, _ = run("naive", prompts)
    fused, counters = run("pallas_interpret", prompts)

    assert set(naive) == set(fused)
    for uid in naive:
        assert fused[uid] == naive[uid], (
            f"greedy divergence fused vs unfused, uid={uid}: "
            f"{fused[uid]} != {naive[uid]}")
    assert counters["prefill_chunk_compiles"] == 1, counters
    assert counters["decode_compiles"] == 1, counters
    print(f"smoke-prefill-fused OK: {len(naive)} requests greedy-identical "
          f"(pallas_interpret vs naive), counters={counters}")


if __name__ == "__main__":
    main()
